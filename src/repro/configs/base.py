"""Model/shape configuration system for the assigned architecture pool.

A model is described by a repeating *superblock* of ``LayerSpec``s plus an
optional unrolled tail.  Heterogeneous stacks (jamba's 1:7 mamba:attn
interleave, gemma3's 5:1 local:global) become a homogeneous scan over
superblocks, which keeps the lowered HLO at ~one-superblock size regardless of
depth -- essential for the 512-device dry-run compile times.

Input-shape sets (assigned): every LM arch carries the same four shapes;
``decode_*``/``long_*`` lower ``serve_step`` (1 new token against a KV/state
cache), not ``train_step``.  ``long_500k`` requires a sub-quadratic path and is
enabled per-arch via ``supports_long_ctx`` (see DESIGN.md Sec. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["LayerSpec", "ModelConfig", "ShapeSpec", "SHAPES", "attn", "mamba", "slstm", "mlstm"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position inside a superblock."""

    kind: str = "attn"        # attn | mamba | slstm | mlstm
    attn_type: str = "global" # global | local (sliding-window)
    moe: bool = False         # MoE FFN instead of dense FFN
    has_mlp: bool = True      # xLSTM blocks carry their own projections


def attn(attn_type: str = "global", moe: bool = False) -> LayerSpec:
    return LayerSpec(kind="attn", attn_type=attn_type, moe=moe)


def mamba(moe: bool = False) -> LayerSpec:
    return LayerSpec(kind="mamba", moe=moe)


def slstm() -> LayerSpec:
    return LayerSpec(kind="slstm", has_mlp=False)


def mlstm() -> LayerSpec:
    return LayerSpec(kind="mlstm", has_mlp=False)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int
    block_pattern: Tuple[LayerSpec, ...]
    n_blocks: int
    tail_pattern: Tuple[LayerSpec, ...] = ()

    # attention
    window: int = 4096              # sliding window for local layers
    rope_theta: float = 10_000.0
    pos_kind: str = "rope"          # rope | sinusoid (whisper) | none (jamba)
    qkv_bias: bool = False
    prefix_lm: int = 0              # bidirectional prefix length (vlm)

    # mlp
    mlp_kind: str = "swiglu"        # swiglu | gelu | relu2

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ssm (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 64             # inner associative-scan chunk

    # encoder-decoder (whisper)
    enc_blocks: int = 0             # encoder superblock count (same pattern)
    cross_attention: bool = False

    # modality frontend (stubs per spec: precomputed embeddings arrive as input)
    frontend: str = "none"          # none | patches | frames
    num_prefix_embeds: int = 0      # patches/frames prepended to the sequence

    # serving
    kv_quant: bool = False          # int8 KV cache (bounded-error, halves HBM)

    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    supports_long_ctx: bool = False
    long_ctx_note: str = ""

    @property
    def n_layers(self) -> int:
        return len(self.block_pattern) * self.n_blocks + len(self.tail_pattern)

    def param_count(self) -> int:
        """Total parameters (for 6*N*D roofline bookkeeping)."""
        from repro.models.params import count_params  # lazy; avoids cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params

        return count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        layers = max(len(self.block_pattern), 1)
        return dataclasses.replace(
            self,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            head_dim=16,
            vocab=256,
            n_blocks=min(self.n_blocks, 2),
            tail_pattern=self.tail_pattern[:1],
            enc_blocks=min(self.enc_blocks, 1),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=32,
            ssm_state=8,
            ssm_chunk=8,
            num_prefix_embeds=min(self.num_prefix_embeds, 8),
            prefix_lm=min(self.prefix_lm, 8),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> Tuple[str, ...]:
    """The assigned shape cells that are runnable for this arch."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_ctx:
        out.append("long_500k")
    return tuple(out)
