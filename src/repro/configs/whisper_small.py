"""whisper-small [audio] -- enc-dec, conv frontend stub [arXiv:2212.04356].

12L (decoder) + 12L encoder, d_model=768 12H (kv=12, head_dim=64) d_ff=3072
vocab=51865.  The conv1d+mel frontend is a STUB per spec: ``input_specs``
supplies 1500 precomputed frame embeddings consumed by the encoder; decoder
layers cross-attend into the encoder memory.  Whisper uses absolute
positions -> parameter-free sinusoids here.  vocab 51865 is indivisible by
the 16-way model axis, exercising the replicate fallback in the partitioner.
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    block_pattern=(attn("global"),),
    n_blocks=12,
    enc_blocks=12,
    cross_attention=True,
    mlp_kind="gelu",
    pos_kind="sinusoid",
    qkv_bias=True,
    frontend="frames",
    num_prefix_embeds=1500,
    tie_embeddings=True,
    supports_long_ctx=False,
    long_ctx_note="enc-dec full attention -- long_500k skipped per spec",
)
