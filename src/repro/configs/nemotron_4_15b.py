"""nemotron-4-15b [dense] -- GQA, squared-ReLU MLP [arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8, head_dim=128) d_ff=24576 vocab=256000.
Squared-ReLU (non-gated) FFN.  48 q heads shard 16-way; the 8 kv heads are
indivisible by the model axis and fall back to replication (partitioner
fallback chain), which the perf log revisits.
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    head_dim=128,
    block_pattern=(attn("global"),),
    n_blocks=32,
    mlp_kind="relu2",
    tie_embeddings=False,
    supports_long_ctx=False,
    long_ctx_note="pure full attention -- long_500k skipped per spec",
)
