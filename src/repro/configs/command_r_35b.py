"""command-r-35b [dense] -- GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=22528 vocab=256000.
No biases anywhere; embeddings tied (Cohere convention).
"""
from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    block_pattern=(attn("global"),),
    n_blocks=40,
    mlp_kind="swiglu",
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    supports_long_ctx=False,
    long_ctx_note="pure full attention -- long_500k skipped per spec",
)
