"""xlstm-125m [ssm] -- sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H d_ff=0 vocab=50304.  xLSTM blocks carry their own
up/down projections (d_ff=0: no separate transformer FFN).  Superblock of 6 =
5 mLSTM + 1 sLSTM (the paper's 7:1-style mostly-mLSTM mix adapted to 12
layers), x2.  Purely recurrent state => long_500k runs with O(1) memory.
"""
from repro.configs.base import ModelConfig, mlstm, slstm

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    block_pattern=tuple([mlstm()] * 5 + [slstm()]),
    n_blocks=2,
    tie_embeddings=True,
    supports_long_ctx=True,
    long_ctx_note="recurrent state only -- O(1) decode memory",
)
